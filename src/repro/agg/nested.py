"""Nested aggregation plans: hierarchical (staged) aggregation first-class.

The paper's multi-hop IA recursion is topology-agnostic: a two-stage
pod/ICI ring is the same algorithm run on a 2-level tree-of-trees, and the
satellite deployments (arXiv:2501.11385, arXiv:2307.08346) make
*cluster-then-relay* aggregation the primary shape — aggregate inside each
cluster/pod over cheap local links, then relay the per-cluster partials to
the PS over the scarce inter-cluster links.

A :class:`NestedPlan` is an ordered stack of :class:`~repro.agg.plan.AggPlan`
stages. Stage s is a *forest* plan (``num_sinks = R_s``): R_s independent
trees over that stage's units, each delivering its partial aggregate to a
distinct sink row. The inter-stage wiring is the sink numbering — stage s's
sink c becomes stage s+1's client c, folded with **weight 1** (client
weights were already applied at stage 0) and its **own error-feedback
tier**, exactly the paper's multi-hop recursion one level up
(``core/hierarchical.py`` is the chain×chain specialization). Per-stage
§V accounting falls out: each stage reports its own :class:`HopStats`, so
the intra-cluster (ICI) and inter-cluster (DCI/ISL-relay) wire split is
measured, not modeled.

``compile_nested`` lowers a stage spec — or a routed
:class:`~repro.topo.routing.NestedTopology` from the cluster-aware router —
into a NestedPlan; :func:`execute_nested` runs one round on host through
the fused :func:`~repro.core.algorithms.level_step` path;
:func:`repro.agg.device.run_nested_segments_local` lowers the same plan
onto the shard_map ring with one mesh axis per stage.

All plan arrays are traced jit arguments (the :class:`AggPlan` contract),
so a :class:`~repro.agg.schedule.TopologySchedule` of nested plans padded
to one per-stage shape compiles to **one** specialization.

Semantics note (documented trade): staged CL-SIA applies Top-Q once per
stage — composition is *not* bit-identical to the flat chain, but both are
instances of the paper's algorithm on a multi-level topology; EF at every
tier keeps the estimator unbiased in the same telescoping sense, and mass
conservation holds per stage (tested). DENSE_IA composition *is* the exact
sum.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.agg.plan import AggPlan, compile_plan, execute
from repro.core.algorithms import AggConfig, HopStats
from repro.topo.tree import PS, AggTree, path_tree

Array = jax.Array


def _ring_chain_tree(num_ranks: int) -> AggTree:
    """The rotated ring's chain (reversed path tree) — local copy to keep
    this module import-cycle free with :mod:`repro.agg.device`."""
    return AggTree(parent=tuple(range(1, num_ranks)) + (PS,))


# ---------------------------------------------------------------------------
# Forest schedule (multi-sink AggPlan) construction
# ---------------------------------------------------------------------------

def _forest_plan(parent: np.ndarray, sink: np.ndarray, *, num_sinks: int,
                 alive: np.ndarray,
                 q_budget: Optional[np.ndarray]) -> AggPlan:
    """Level-schedule a forest: ``parent[i]`` ∈ 0..K−1 or :data:`PS`;
    roots deliver to sink row ``k + sink[i]``. Deepest level first, exactly
    :func:`repro.topo.tree.build_schedule` generalized to R sinks."""
    k = len(parent)
    depth = np.zeros((k,), np.int64)
    for i in range(k):
        d, node, hops = 1, i, 0
        while parent[node] != PS:
            node = int(parent[node])
            if not 0 <= node < k:
                raise ValueError(f"parent index {node} out of range")
            d += 1
            hops += 1
            if hops > k:
                raise ValueError("cycle in aggregation forest")
        depth[i] = d
    lmax = int(depth.max()) if k else 0
    levels = [np.where(depth == l)[0] for l in range(lmax, 0, -1)]
    w = max((len(lv) for lv in levels), default=1)

    node_id = np.full((lmax, w), k, np.int32)
    slot_mask = np.zeros((lmax, w), np.float32)
    parent_row = np.full((lmax, w), k + num_sinks, np.int32)
    flat_pos = np.zeros((k,), np.int64)
    for li, members in enumerate(levels):
        for wi, node in enumerate(members):
            node_id[li, wi] = node
            slot_mask[li, wi] = 1.0
            p = int(parent[node])
            parent_row[li, wi] = (k + int(sink[node])) if p == PS else p
            flat_pos[node] = li * w + wi
    return AggPlan(node_id=node_id, slot_mask=slot_mask,
                   parent_row=parent_row,
                   flat_pos=flat_pos.astype(np.int32),
                   alive=np.asarray(alive, np.float32), q_budget=q_budget,
                   num_clients=k, num_sinks=num_sinks)


# ---------------------------------------------------------------------------
# Clustered stage form (the device lowering's view of a forest stage)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClusteredStage:
    """Per-cluster stacked single-sink plans of one forest stage.

    Leaves carry a leading cluster axis: ``node_id[c]`` etc. is cluster c's
    local :class:`AggPlan` (over ``num_units`` local nodes, local sink row
    ``num_units``, trash ``num_units + 1``); ``members[c, m]`` is the
    global unit index of local node m (pad = the stage's unit count).
    ``flat_pos`` of unit-padding locals is a clients/segments-kernel-only
    placeholder (0) — those locals never appear in the schedule.

    This is what :func:`repro.agg.device.run_nested_segments_local` runs:
    rank groups select their cluster's subplan by mesh index, so per-pod
    trees travel as traced ``[C, L, W]`` arrays under one specialization.
    :meth:`mesh_aligned` tells whether cluster c is exactly units
    ``c·M .. c·M + M − 1`` — the layout the (pod, data) mesh requires
    (checkable only while ``members`` is still a host constant; it is a
    leaf, not part of the jit-specialization key).
    """

    node_id: np.ndarray        # [C, L, W] int32 (local ids; pad = M)
    slot_mask: np.ndarray      # [C, L, W] float32
    parent_row: np.ndarray     # [C, L, W] int32 (local; M = sink, M+1 trash)
    flat_pos: np.ndarray       # [C, M] int32
    alive: np.ndarray          # [C, M] float32
    q_budget: Optional[np.ndarray]   # [C, M] int32
    members: np.ndarray        # [C, M] int32 (global unit index; pad = K)
    num_units: int = 0         # M (static)

    def mesh_aligned(self):
        """True/False when ``members`` is a host constant (cluster c ==
        units ``c·M..c·M+M−1``); None when traced (callers that already
        validated at compile time may proceed)."""
        if isinstance(self.members, jax.core.Tracer):
            return None
        m = np.asarray(self.members)
        return bool(np.all(m.reshape(-1) == np.arange(m.size)))

    @property
    def num_clusters(self) -> int:
        return int(self.node_id.shape[0])

    @property
    def shape(self) -> tuple:
        return tuple(self.node_id.shape)

    def subplan(self, c) -> AggPlan:
        """Cluster c's local single-sink plan. ``c`` may be a Python int
        (static numpy subplan) or a traced index (traced leaves — the
        device lowering's per-rank selection)."""
        arrays = (self.node_id, self.slot_mask, self.parent_row,
                  self.flat_pos, self.alive, self.q_budget)
        if isinstance(c, (int, np.integer)):
            take = lambda a: None if a is None else np.asarray(a)[int(c)]
        else:
            take = lambda a: None if a is None else jnp.asarray(a)[c]
        node_id, slot_mask, parent_row, flat_pos, alive, qb = map(take,
                                                                  arrays)
        return AggPlan(node_id=node_id, slot_mask=slot_mask,
                       parent_row=parent_row, flat_pos=flat_pos,
                       alive=alive, q_budget=qb,
                       num_clients=self.num_units, num_sinks=1)

    def uniform(self) -> bool:
        """True when every cluster runs an identical local plan (static
        arrays only) — the device lowering then keeps the static per-slot
        ppermute transport instead of the butterfly."""
        leaves = [self.node_id, self.slot_mask, self.parent_row,
                  self.alive]
        if self.q_budget is not None:
            leaves.append(self.q_budget)
        for a in leaves:
            if isinstance(a, jax.core.Tracer):
                return False
            a = np.asarray(a)
            if a.shape[0] > 1 and not np.all(a == a[:1]):
                return False
        return True

    def pad(self, shape: tuple) -> "ClusteredStage":
        """Re-pad every cluster's (L, W) — schedule-sharing companion of
        :meth:`AggPlan.pad`."""
        c, big_l, big_w = shape
        if (c, big_l, big_w) == self.shape:
            return self
        if c != self.shape[0]:
            raise ValueError(f"cluster count {self.shape[0]} != {c}")
        plans = [self.subplan(i).pad((big_l, big_w)) for i in range(c)]
        return ClusteredStage(
            node_id=np.stack([p.node_id for p in plans]),
            slot_mask=np.stack([p.slot_mask for p in plans]),
            parent_row=np.stack([p.parent_row for p in plans]),
            flat_pos=np.stack([p.flat_pos for p in plans]),
            alive=self.alive, q_budget=self.q_budget, members=self.members,
            num_units=self.num_units)


def _clustered_flatten(s: ClusteredStage):
    return ((s.node_id, s.slot_mask, s.parent_row, s.flat_pos, s.alive,
             s.q_budget, s.members), s.num_units)


def _clustered_unflatten(num_units, leaves):
    (node_id, slot_mask, parent_row, flat_pos, alive, q_budget,
     members) = leaves
    return ClusteredStage(node_id=node_id, slot_mask=slot_mask,
                          parent_row=parent_row, flat_pos=flat_pos,
                          alive=alive, q_budget=q_budget, members=members,
                          num_units=num_units)


jax.tree_util.register_pytree_node(ClusteredStage, _clustered_flatten,
                                   _clustered_unflatten)


def _pad_units(plan: AggPlan, m_big: int) -> AggPlan:
    """Grow a single-sink plan from m to M local nodes. The added locals
    never appear in the schedule (kernel consumers skip them); only the
    dummy/sink/trash row ids shift from (m, m, m+1) to (M, M, M+1)."""
    m = plan.num_clients
    if m == m_big:
        return plan
    node_id = np.where(np.asarray(plan.node_id) == m, m_big,
                       plan.node_id).astype(np.int32)
    par = np.asarray(plan.parent_row)
    parent_row = np.where(par == m, m_big,
                          np.where(par == m + 1, m_big + 1,
                                   par)).astype(np.int32)
    pad = m_big - m
    qb = (None if plan.q_budget is None
          else np.concatenate([np.asarray(plan.q_budget, np.int32),
                               np.zeros((pad,), np.int32)]))
    return AggPlan(
        node_id=node_id, slot_mask=plan.slot_mask, parent_row=parent_row,
        flat_pos=np.concatenate([np.asarray(plan.flat_pos, np.int32),
                                 np.zeros((pad,), np.int32)]),
        alive=np.concatenate([np.asarray(plan.alive, np.float32),
                              np.zeros((pad,), np.float32)]),
        q_budget=qb, num_clients=m_big, num_sinks=1)


# ---------------------------------------------------------------------------
# NestedPlan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NestedPlan:
    """An ordered stack of forest :class:`AggPlan` stages (see module doc).

    ``stages[s]`` is the stage-s forest over ``stage_units[s]`` units with
    ``num_sinks == stage_units[s+1]`` (1 for the last stage — the PS).
    ``clustered[s]`` (stages 0..S−2) is the same forest in per-cluster
    stacked form, the device lowering's selection structure.

    Registered as a jax pytree; every array is a traced jit argument, so
    same-``shape`` nested plans share one specialization (tested).
    """

    stages: tuple                 # tuple[AggPlan, ...]
    clustered: tuple = ()         # tuple[ClusteredStage, ...], len S−1

    def __post_init__(self):
        if not self.stages:
            raise ValueError("nested plan needs at least one stage")
        for s in range(len(self.stages) - 1):
            r, nxt = self.stages[s].num_sinks, self.stages[s + 1].num_clients
            if r != nxt:
                raise ValueError(
                    f"stage {s} has {r} sinks but stage {s + 1} has {nxt} "
                    f"clients — the sink numbering is the wiring map")
        if self.stages[-1].num_sinks != 1:
            raise ValueError("the last stage must aggregate to one PS sink")

    @property
    def num_clients(self) -> int:
        return self.stages[0].num_clients

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def stage_units(self) -> tuple:
        return tuple(s.num_clients for s in self.stages)

    @property
    def q_budget(self):
        """Stage-0 per-client budgets (TopologySchedule compatibility)."""
        return self.stages[0].q_budget

    def client_alive(self):
        """Effective [K] client aliveness: a client's mass reaches the PS
        only if the client AND its whole relay chain of cluster units are
        alive (a quotient-unreachable cluster forwards nothing — its
        clients must not be counted in the PS weight denominator).
        Traced-compatible (jnp ops over the plan leaves)."""
        alive = jnp.asarray(self.stages[-1].alive, jnp.float32)
        for s in range(self.num_stages - 2, -1, -1):
            members = jnp.asarray(self.clustered[s].members)     # [C, M]
            k_s = self.stages[s].num_clients
            down = jnp.zeros((k_s + 1,), alive.dtype).at[
                members.reshape(-1)].set(
                jnp.repeat(alive, members.shape[1]))[:k_s]
            alive = jnp.asarray(self.stages[s].alive,
                                jnp.float32) * down
        return alive

    @property
    def shape(self) -> tuple:
        """Per-stage jit-specialization key: each stage's padded (L, W),
        with the clustered form's (C, L, W) appended where present."""
        sig = []
        for s, st in enumerate(self.stages):
            entry = st.shape
            if s < len(self.clustered):
                entry = entry + self.clustered[s].shape
            sig.append(entry)
        return tuple(sig)

    def pad(self, shape: tuple) -> "NestedPlan":
        """Re-pad every stage to the given :attr:`shape` signature —
        bit-exact, the schedule-sharing companion of :meth:`AggPlan.pad`."""
        if tuple(shape) == self.shape:
            return self
        if len(shape) != len(self.stages):
            raise ValueError(f"shape has {len(shape)} stages, plan has "
                             f"{len(self.stages)}")
        stages, clustered = [], []
        for s, (st, sig) in enumerate(zip(self.stages, shape)):
            stages.append(st.pad(tuple(sig[:2])))
            if s < len(self.clustered):
                clustered.append(self.clustered[s].pad(tuple(sig[2:])))
        return NestedPlan(stages=tuple(stages), clustered=tuple(clustered))


def _nested_flatten(p: NestedPlan):
    return ((p.stages, p.clustered), None)


def _nested_unflatten(_, children):
    stages, clustered = children
    return NestedPlan(stages=tuple(stages), clustered=tuple(clustered))


jax.tree_util.register_pytree_node(NestedPlan, _nested_flatten,
                                   _nested_unflatten)


def nested_common_shape(plans) -> tuple:
    """Elementwise-max per-stage shape signature over nested plans."""
    shapes = [p.shape for p in plans]
    if not shapes:
        raise ValueError("no plans")
    n = len(shapes[0])
    if any(len(s) != n for s in shapes):
        raise ValueError("nested plans must have the same stage count")
    out = []
    for s in range(n):
        entries = [sh[s] for sh in shapes]
        if len({len(e) for e in entries}) != 1:
            raise ValueError("nested plans must agree on clustered-form "
                             "presence per stage")
        out.append(tuple(max(e[i] for e in entries)
                         for i in range(len(entries[0]))))
    return tuple(out)


# ---------------------------------------------------------------------------
# compile_nested
# ---------------------------------------------------------------------------

def _local_tree(topo: Any, m: int) -> AggTree:
    if topo is None:
        return path_tree(m)       # members[0] adjacent to the sink
    if isinstance(topo, AggTree):
        tree = topo
    elif isinstance(topo, int):
        tree = path_tree(topo)
    else:
        from repro.agg.plan import as_tree
        tree = as_tree(topo, m)
    if tree.num_clients != m:
        raise ValueError(f"cluster tree has {tree.num_clients} nodes for "
                         f"{m} members")
    return tree


def _compile_stage(clusters: Sequence, k: int,
                   q_budget: Optional[np.ndarray],
                   build_clustered: bool):
    """One stage spec → (forest AggPlan, Optional[ClusteredStage]).

    ``clusters`` is ``[(members, topology), ...]``: members are unit
    indices of *this* stage, topology an :class:`AggTree` over
    ``len(members)`` local nodes (None → the paper chain in member order,
    members[0] adjacent to the sink). Members must partition 0..k−1.
    """
    num_sinks = len(clusters)
    parent = np.full((k,), PS, np.int64)
    sink = np.zeros((k,), np.int64)
    alive = np.ones((k,), np.float32)
    seen: set = set()
    local_plans, member_rows = [], []
    for c, spec in enumerate(clusters):
        members, topo = (spec if isinstance(spec, tuple) and len(spec) == 2
                         and not isinstance(spec[0], (int, np.integer))
                         else (spec, None))
        members = [int(i) for i in np.asarray(members, np.int64).reshape(-1)]
        if not members:
            raise ValueError(f"cluster {c} is empty")
        dup = seen.intersection(members)
        if dup:
            raise ValueError(f"units {sorted(dup)} appear in two clusters")
        seen.update(members)
        tree = _local_tree(topo, len(members))
        for i, g in enumerate(members):
            p = tree.parent[i]
            parent[g] = PS if p == PS else members[p]
            sink[g] = c
            if tree.reachable is not None and not tree.reachable[i]:
                alive[g] = 0.0
        if build_clustered:
            qb_c = (None if q_budget is None
                    else np.asarray(q_budget, np.int32)[members])
            local_plans.append(compile_plan(tree, q_budget=qb_c))
            member_rows.append(members)
    if seen != set(range(k)):
        missing = sorted(set(range(k)) - seen)
        raise ValueError(f"clusters must partition 0..{k - 1}; missing "
                         f"{missing}")

    plan = _forest_plan(parent, sink, num_sinks=num_sinks, alive=alive,
                        q_budget=(None if q_budget is None
                                  else np.asarray(q_budget,
                                                  np.int32).reshape(-1)))
    if not build_clustered:
        return plan, None

    m_big = max(len(m) for m in member_rows)
    shape = (max(p.shape[0] for p in local_plans),
             max(p.shape[1] for p in local_plans))
    padded = [_pad_units(p.pad(shape), m_big) for p in local_plans]
    members = np.full((num_sinks, m_big), k, np.int32)
    for c, row in enumerate(member_rows):
        members[c, :len(row)] = row
    clustered = ClusteredStage(
        node_id=np.stack([p.node_id for p in padded]),
        slot_mask=np.stack([p.slot_mask for p in padded]),
        parent_row=np.stack([p.parent_row for p in padded]),
        flat_pos=np.stack([p.flat_pos for p in padded]),
        alive=np.stack([p.alive for p in padded]),
        q_budget=(None if q_budget is None
                  else np.stack([np.asarray(p.q_budget, np.int32)
                                 for p in padded])),
        members=members, num_units=m_big)
    return plan, clustered


def compile_nested(topology: Any, *,
                   num_clients: Optional[int] = None,
                   pad_to: Optional[tuple] = None,
                   q_budget: Optional[np.ndarray] = None) -> NestedPlan:
    """Lower a staged topology to its canonical :class:`NestedPlan`.

    ``topology`` is one of

    * a :class:`NestedPlan` — returned (re-padded when ``pad_to``);
    * a :class:`repro.topo.routing.NestedTopology` — the cluster-aware
      router's output (clusters + intra trees + inter relay tree);
    * a stage spec: a sequence of stages, each a sequence of clusters
      ``(members, topo)`` (``topo`` None → chain in member order). Stage
      s's clusters partition stage s's units; stage s+1's unit c is stage
      s's cluster c; the last stage has exactly one cluster (the PS tree).

    ``q_budget`` attaches stage-0 per-client budgets. ``pad_to`` is a
    :attr:`NestedPlan.shape` signature for schedule sharing.
    """
    if isinstance(topology, NestedPlan):
        return topology if pad_to is None else topology.pad(pad_to)
    if hasattr(topology, "nested_stages"):      # NestedTopology
        topology = topology.nested_stages()
    stages_spec = list(topology)
    if not stages_spec:
        raise ValueError("empty stage spec")
    if len(stages_spec[-1]) != 1:
        raise ValueError("the last stage must be a single cluster rooted "
                         "at the PS")

    # infer stage-0 unit count
    def spec_members(spec):
        if (isinstance(spec, tuple) and len(spec) == 2
                and not isinstance(spec[0], (int, np.integer))):
            spec = spec[0]
        return np.asarray(spec, np.int64).reshape(-1)

    k0 = num_clients
    if k0 is None:
        k0 = 1 + max(int(i) for spec in stages_spec[0]
                     for i in spec_members(spec))

    stages, clustered = [], []
    k = k0
    for s, spec in enumerate(stages_spec):
        last = s == len(stages_spec) - 1
        plan, cl = _compile_stage(
            spec, k, q_budget if s == 0 else None,
            build_clustered=not last)
        stages.append(plan)
        if cl is not None:
            clustered.append(cl)
        k = plan.num_sinks
    nested = NestedPlan(stages=tuple(stages), clustered=tuple(clustered))
    if pad_to is not None:
        nested = nested.pad(tuple(pad_to))
    return nested


def pod_ring_nested(k_pod: int, k_data: int, *,
                    q_budget: Optional[np.ndarray] = None) -> NestedPlan:
    """The two-stage pod/ICI ring as a nested plan (chain×chain).

    Stage 0: one rotated-ring chain per pod over its ``k_data`` members
    (client ``p·K_d + r`` ↔ mesh rank ``(p, r)``); stage 1: the ring chain
    over the ``k_pod`` pod partials. This is exactly the topology
    ``core/hierarchical.py`` hand-composed — its device lowering is
    bit-exact to the historic two-stage ``rotated_ring_local`` pair.
    """
    intra = _ring_chain_tree(k_data)
    stage0 = [(tuple(range(p * k_data, (p + 1) * k_data)), intra)
              for p in range(k_pod)]
    stage1 = [(tuple(range(k_pod)), _ring_chain_tree(k_pod))]
    return compile_nested([stage0, stage1],
                          num_clients=k_pod * k_data, q_budget=q_budget)


def as_nested(topology: Any, num_clients: Optional[int] = None
              ) -> Optional[NestedPlan]:
    """Coerce nested-shaped topologies to a :class:`NestedPlan`; ``None``
    for everything else (flat topologies keep their existing paths)."""
    if isinstance(topology, NestedPlan):
        return topology
    if hasattr(topology, "nested_stages"):
        return compile_nested(topology, num_clients=num_clients)
    return None


# ---------------------------------------------------------------------------
# execute_nested — one staged round on host
# ---------------------------------------------------------------------------

class NestedResult(NamedTuple):
    aggregate: Array      # [d] what the PS receives after the last stage
    e_new: Array          # [K, d] stage-0 (client) EF, client index order
    stage_e_new: tuple    # per upper stage: [K_s, d] EF tier
    stats: HopStats       # stage-0 per-client stats, leaves [K]
    stage_stats: tuple    # per upper stage: HopStats, leaves [K_s]


def zero_stage_ef(nested: NestedPlan, d: int, dtype=jnp.float32) -> tuple:
    """Fresh upper-tier EF buffers, one [K_s, d] array per stage ≥ 1."""
    return tuple(jnp.zeros((k, d), dtype)
                 for k in nested.stage_units[1:])


def execute_nested(
    cfg: AggConfig,
    nested: NestedPlan,
    grads: Array,                  # [K, d] per-client effective gradients
    e: Array,                      # [K, d] client-level EF memory
    weights: Array,                # [K]    D_k
    *,
    stage_e: Optional[Sequence[Array]] = None,   # EF tiers, stages ≥ 1
    global_mask: Optional[Array] = None,         # [d] TCS mask m^t
    participate: Optional[Array] = None,         # [K] 0/1 straggler mask
    stage_cfgs: Optional[Sequence[AggConfig]] = None,
) -> NestedResult:
    """One staged aggregation round over a compiled :class:`NestedPlan`.

    Stage 0 is :func:`repro.agg.plan.execute` on the client forest (same
    contract, incl. ``participate``/``q_budget``/straggler semantics);
    every later stage re-enters ``execute`` with the previous stage's sink
    partials as its "gradients", weight 1, and that stage's EF tier —
    the paper's recursion one level up, running through the same fused
    ``level_step`` hot path. ``stage_cfgs`` optionally overrides the
    AggConfig per stage (e.g. a larger inter-cluster budget); default: one
    ``cfg`` for every tier, matching ``hierarchical_ring_local``.
    """
    k, d = grads.shape
    if nested.num_clients != k:
        raise ValueError(f"nested plan has {nested.num_clients} clients, "
                         f"grads {k}")
    n_stages = nested.num_stages
    cfgs = list(stage_cfgs) if stage_cfgs is not None else [cfg] * n_stages
    if len(cfgs) != n_stages:
        raise ValueError(f"stage_cfgs has {len(cfgs)} entries for "
                         f"{n_stages} stages")
    if stage_e is None:
        stage_e = zero_stage_ef(nested, d, grads.dtype)
    stage_e = tuple(stage_e)
    if len(stage_e) != n_stages - 1:
        raise ValueError(f"stage_e needs {n_stages - 1} EF tiers, got "
                         f"{len(stage_e)}")

    res0 = execute(cfgs[0], nested.stages[0], grads, e, weights,
                   global_mask=global_mask, participate=participate)
    agg = res0.aggregate
    if nested.stages[0].num_sinks == 1:
        agg = agg[None]
    stage_e_new, stage_stats = [], []
    for s in range(1, n_stages):
        plan = nested.stages[s]
        ones = jnp.ones((plan.num_clients,), jnp.float32)
        res = execute(cfgs[s], plan, agg, stage_e[s - 1], ones,
                      global_mask=global_mask)
        stage_e_new.append(res.e_new)
        stage_stats.append(res.stats)
        agg = res.aggregate
        if plan.num_sinks == 1:
            agg = agg[None]
    return NestedResult(aggregate=agg[0], e_new=res0.e_new,
                        stage_e_new=tuple(stage_e_new), stats=res0.stats,
                        stage_stats=tuple(stage_stats))
