"""Topology-polymorphic aggregation: ``compile_plan`` + one ``execute``.

Every aggregation topology the repo knows — the paper's linear chain, a
permuted/healed chain order, or a routed constellation :class:`AggTree` —
lowers to one canonical representation, the :class:`AggPlan`: a padded
``(L, W)`` level schedule (L levels run sequentially, up to W nodes per
level run concurrently). ``execute(cfg, plan, ...)`` is the single round
entry point; it is bit-exact to :func:`repro.core.chain.run_chain` on
chain plans and subsumes :func:`repro.topo.tree.run_tree` (which now
delegates here).

The plan's arrays are *traced* jit arguments, not Python constants, so the
compiled round is specialized only on the padded ``(L, W)`` shape — every
topology padded to the same shape shares one XLA executable. That is what
makes time-varying topologies (:class:`repro.agg.schedule.TopologySchedule`)
cheap: a round-per-graph LEO schedule re-routes continuously but triggers
exactly one trace.

Plans optionally carry per-client ``q_budget`` (int32 [K]) — the
bandwidth-aware Top-Q budgets of :func:`bandwidth_budgets`, where narrow
uplinks get proportionally smaller local budgets. Without a budget the node
steps run the paper's static-``q`` exact Top-Q, bit-identical to before.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import (AggConfig, AggKind, HopStats, level_step,
                                   level_step_batched)
from repro.topo.tree import PS, AggTree, build_schedule, path_tree

Array = jax.Array

Topology = Union[int, AggTree, Sequence, np.ndarray]


@dataclasses.dataclass(frozen=True)
class AggPlan:
    """Canonical padded level schedule — the compiled form of a topology.

    ``node_id[l, w]`` is the client run in slot w of level l, deepest level
    first (padding slots hold K, a zero dummy row); ``slot_mask`` is 1.0 for
    real slots; ``parent_row[l, w]`` is the inbox row receiving that slot's
    γ (client index, K..K+R−1 for the R sink rows, K+R trash row for
    padding — single-sink plans have R = 1 and their sink K *is* the PS,
    exactly the historic layout); ``flat_pos[k]`` maps client k back out of
    schedule order. ``alive[k]`` is 0.0 for stranded stubs (clients routing
    could not reach) — folded into ``participate`` by :func:`execute`.
    ``q_budget`` (optional int32 [K]) carries per-client local Top-Q
    budgets.

    ``num_sinks`` > 1 makes the plan a *forest*: R independent trees whose
    roots deliver to distinct sink rows — the stage form of a
    :class:`repro.agg.nested.NestedPlan`, where stage s's sink c feeds
    stage s+1's client c.

    Registered as a jax pytree: arrays are leaves (traced jit arguments),
    ``num_clients``/``num_sinks`` are static. Two plans with the same
    ``(L, W)``, sink count and leaf dtypes therefore share one jit
    specialization.
    """

    node_id: np.ndarray       # [L, W] int32
    slot_mask: np.ndarray     # [L, W] float32
    parent_row: np.ndarray    # [L, W] int32
    flat_pos: np.ndarray      # [K] int32
    alive: np.ndarray         # [K] float32
    q_budget: Optional[np.ndarray] = None   # [K] int32
    num_clients: int = 0
    num_sinks: int = 1

    @property
    def shape(self) -> tuple:
        """The padded ``(L, W)`` — the jit-specialization key."""
        return tuple(self.node_id.shape)

    def pad(self, shape: tuple) -> "AggPlan":
        """Re-pad to a larger ``(L, W)`` (bit-exact: padding slots are
        no-ops — they run the zero dummy row and scatter into the trash
        row)."""
        big_l, big_w = shape
        l, w = self.shape
        if (big_l, big_w) == (l, w):
            return self
        if big_l < l or big_w < w:
            raise ValueError(f"cannot shrink plan {self.shape} to {shape}")
        k = self.num_clients
        node_id = np.full((big_l, big_w), k, np.int32)
        slot_mask = np.zeros((big_l, big_w), np.float32)
        parent_row = np.full((big_l, big_w), k + self.num_sinks, np.int32)
        node_id[:l, :w] = self.node_id
        slot_mask[:l, :w] = self.slot_mask
        parent_row[:l, :w] = self.parent_row
        li, wi = np.divmod(np.asarray(self.flat_pos, np.int64), w)
        flat_pos = (li * big_w + wi).astype(np.int32)
        return AggPlan(node_id=node_id, slot_mask=slot_mask,
                       parent_row=parent_row, flat_pos=flat_pos,
                       alive=self.alive, q_budget=self.q_budget,
                       num_clients=k, num_sinks=self.num_sinks)


def _plan_flatten(p: AggPlan):
    return ((p.node_id, p.slot_mask, p.parent_row, p.flat_pos, p.alive,
             p.q_budget), (p.num_clients, p.num_sinks))


def _plan_unflatten(aux, leaves):
    num_clients, num_sinks = aux
    node_id, slot_mask, parent_row, flat_pos, alive, q_budget = leaves
    return AggPlan(node_id=node_id, slot_mask=slot_mask,
                   parent_row=parent_row, flat_pos=flat_pos, alive=alive,
                   q_budget=q_budget, num_clients=num_clients,
                   num_sinks=num_sinks)


jax.tree_util.register_pytree_node(AggPlan, _plan_flatten, _plan_unflatten)


# ---------------------------------------------------------------------------
# compile_plan
# ---------------------------------------------------------------------------

def _order_to_tree(order: np.ndarray, num_clients: Optional[int]) -> AggTree:
    """A (possibly permuted) chain order → the equivalent path tree.

    ``order[0]`` is the client adjacent to the PS; ``order[-1]`` the far
    end (matching ``run_chain_with_topology``). Must be a full permutation —
    express exclusions via ``participate`` or by routing a tree.
    """
    order = np.asarray(order, np.int64).reshape(-1)
    k = num_clients if num_clients is not None else len(order)
    if sorted(order.tolist()) != list(range(k)):
        raise ValueError(
            f"chain order must be a permutation of 0..{k - 1}; got "
            f"{order.tolist()} (exclude nodes via participate, not order)")
    parent = np.empty((k,), np.int64)
    parent[order[0]] = PS
    parent[order[1:]] = order[:-1]
    return AggTree(parent=tuple(int(p) for p in parent))


def as_tree(topology: Topology, num_clients: Optional[int] = None) -> AggTree:
    """Coerce any supported topology description to an :class:`AggTree`.

    * ``int K`` — the paper's identity chain over K clients;
    * :class:`AggTree` — used as-is;
    * 1-D int sequence — a (healed/permuted) chain visiting order;
    * anything with a ``.tree()`` method (``repro.fed.topology``'s
      ``TreeTopology``) or a ``ConstellationGraph`` — routed via the
      shortest-path policy.
    """
    if isinstance(topology, AggTree):
        return topology
    if isinstance(topology, int):
        return path_tree(topology)
    if hasattr(topology, "tree") and callable(topology.tree):
        return topology.tree()
    if hasattr(topology, "client_nodes"):         # ConstellationGraph
        from repro.topo.routing import shortest_path_tree
        return shortest_path_tree(topology)
    if hasattr(topology, "order") and callable(topology.order):
        return _order_to_tree(np.asarray(topology.order()), num_clients)
    return _order_to_tree(np.asarray(topology), num_clients)


def compile_plan(topology: Topology, *,
                 num_clients: Optional[int] = None,
                 pad_to: Optional[tuple] = None,
                 q_budget: Optional[np.ndarray] = None) -> AggPlan:
    """Lower a topology to its canonical :class:`AggPlan`.

    ``pad_to=(L, W)`` pads the level schedule so plans from different
    topologies share one jit specialization (see
    :class:`repro.agg.schedule.TopologySchedule`). ``q_budget`` attaches
    per-client local Top-Q budgets (:func:`bandwidth_budgets`).
    """
    tree = as_tree(topology, num_clients)
    k = tree.num_clients
    sched = build_schedule(tree)
    alive = (np.ones((k,), np.float32) if tree.reachable is None
             else np.asarray(tree.reachable, np.float32))
    qb = None
    if q_budget is not None:
        qb = np.asarray(q_budget, np.int32).reshape(-1)
        if qb.shape != (k,):
            raise ValueError(f"q_budget must be [K={k}]; got {qb.shape}")
    plan = AggPlan(node_id=np.asarray(sched.node_id, np.int32),
                   slot_mask=np.asarray(sched.slot_mask, np.float32),
                   parent_row=np.asarray(sched.parent_row, np.int32),
                   flat_pos=np.asarray(sched.flat_pos, np.int32),
                   alive=alive, q_budget=qb, num_clients=k)
    if pad_to is not None:
        plan = plan.pad(tuple(pad_to))
    return plan


# ---------------------------------------------------------------------------
# Bandwidth-aware budgets
# ---------------------------------------------------------------------------

def bandwidth_budgets(cfg: AggConfig, tree: AggTree, *,
                      floor: int = 1) -> np.ndarray:
    """Per-client local Top-Q budgets scaled by uplink bandwidth.

    ``q_k = max(floor, round(q_base · bw_k / max bw))`` where ``q_base`` is
    the algorithm's local budget (``q``, or ``q_local`` for the TC
    variants). Narrow uplinks transmit fewer nonzeros, so total §V bits
    drop versus the uniform budget on any heterogeneous-bandwidth tree
    (zero-bandwidth stubs get the floor; they never transmit anyway).
    """
    if tree.uplink_bw_bps is None:
        raise ValueError("tree has no per-link bandwidth (built by hand?) — "
                         "route it from a ConstellationGraph")
    bw = np.asarray(tree.uplink_bw_bps, np.float64)
    base = (cfg.q_local if cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA)
            else cfg.q)
    pos = bw[bw > 0]
    if pos.size == 0:
        return np.full((tree.num_clients,), floor, np.int32)
    scaled = np.round(base * bw / pos.max())
    return np.where(bw > 0, np.maximum(floor, scaled),
                    floor).astype(np.int32)


# ---------------------------------------------------------------------------
# execute — the single round entry point
# ---------------------------------------------------------------------------

class RoundResult(NamedTuple):
    aggregate: Array      # what the PS receives (Σ over its children), [d];
                          # forest plans (num_sinks R > 1) get [R, d] — one
                          # partial aggregate per sink, in sink order
    e_new: Array          # updated EF memory, [K, d] (client index order)
    stats: HopStats       # per-hop stats, leaves [K] (client index order)


def execute(
    cfg: AggConfig,
    plan: AggPlan,
    grads: Array,                  # [K, d] per-client effective gradients g_k
    e: Array,                      # [K, d] EF memory
    weights: Array,                # [K]    D_k
    *,
    global_mask: Optional[Array] = None,   # [d] TCS mask m^t (TC algorithms)
    participate: Optional[Array] = None,   # [K] 0/1 straggler mask
) -> RoundResult:
    """One aggregation round over a compiled plan (any topology).

    Same contract as :func:`repro.core.chain.run_chain` with the topology
    factored into ``plan``; bit-exact to ``run_chain`` on chain plans and
    invariant under padding. A ``lax.scan`` walks the L levels deepest
    first while :func:`repro.core.algorithms.level_step` runs every node
    of a level concurrently — the historic ``vmap`` of the scalar node
    step off-TPU, one batched Pallas call per level when the fused kernel
    path is on; children's partial aggregates merge at each parent via a
    masked scatter-add (padding slots run the zero dummy row and target
    the trash row, so they are no-ops).
    """
    k, d = grads.shape
    if plan.num_clients != k:
        raise ValueError(f"plan has {plan.num_clients} clients, grads {k}")
    if global_mask is None:
        global_mask = jnp.zeros((d,), grads.dtype)
    if participate is None:
        participate = jnp.ones((k,), grads.dtype)
    participate = participate * jnp.asarray(plan.alive, grads.dtype)
    lvl = level_step(cfg)

    # one zero dummy row (index K) backs the padding slots
    zrow = jnp.zeros((1, d), grads.dtype)
    g_ext = jnp.concatenate([grads, zrow])
    e_ext = jnp.concatenate([e, zrow])
    w_ext = jnp.concatenate([weights, jnp.zeros((1,), weights.dtype)])
    p_ext = jnp.concatenate(
        [participate, jnp.zeros((1,), participate.dtype)])
    q_ext = None
    if plan.q_budget is not None:
        q_ext = jnp.concatenate([jnp.asarray(plan.q_budget, jnp.int32),
                                 jnp.zeros((1,), jnp.int32)])

    def body(inbox, xs):
        ids, mask, par = xs
        gamma_out, e_new, stats = lvl(
            g_ext[ids], inbox[ids], e_ext[ids], w_ext[ids], p_ext[ids],
            global_mask, None if q_ext is None else q_ext[ids], mask)
        inbox = inbox.at[par].add(gamma_out * mask[:, None])
        return inbox, (e_new, stats)

    # inbox rows: 0..K−1 per-client incoming sums, K..K+R−1 the sink rows
    # (R = 1: the PS), K+R = trash
    r_sinks = plan.num_sinks
    inbox0 = jnp.zeros((k + r_sinks + 1, d), grads.dtype)
    inbox, (e_lvl, st_lvl) = jax.lax.scan(
        body, inbox0,
        (jnp.asarray(plan.node_id), jnp.asarray(plan.slot_mask),
         jnp.asarray(plan.parent_row)))

    # scan outputs are [L, W, ...] in schedule order → client index order
    pos = jnp.asarray(plan.flat_pos)
    e_new = e_lvl.reshape(-1, d)[pos]
    stats = jax.tree.map(
        lambda s: s.reshape((-1,) + s.shape[2:])[pos], st_lvl)
    agg = inbox[k] if r_sinks == 1 else inbox[k:k + r_sinks]
    return RoundResult(aggregate=agg, e_new=e_new, stats=stats)


# ---------------------------------------------------------------------------
# execute_batched — B cohorts per launch (multi-tenant rounds)
# ---------------------------------------------------------------------------

def stack_plans(plans: Sequence[AggPlan]) -> AggPlan:
    """Stack B shape-identical plans into one cohort-batched plan.

    The result's array leaves carry a leading cohort axis ``[B, ...]``
    (still traced jit args — B plans with one ``(L, W)`` shape share one
    specialization of :func:`execute_batched`). Plans must agree on shape,
    client count, sink count and ``q_budget`` presence — pad heterogeneous
    plans to a common shape first (:func:`repro.agg.schedule.common_shape`;
    the bucket packing of :class:`repro.agg.batching.RoundScheduler` does
    this for you). A stacked plan is only consumable by the batched
    executors; ``pad`` it *before* stacking.
    """
    if not plans:
        raise ValueError("stack_plans needs at least one plan")
    p0 = plans[0]
    for p in plans[1:]:
        if p.shape != p0.shape:
            raise ValueError(f"plan shapes differ: {p.shape} vs {p0.shape} "
                             f"(pad to a common shape first)")
        if (p.num_clients, p.num_sinks) != (p0.num_clients, p0.num_sinks):
            raise ValueError("stacked plans must share client/sink counts")
        if (p.q_budget is None) != (p0.q_budget is None):
            raise ValueError("stacked plans must agree on q_budget presence")
    stk = lambda leaf: np.stack([np.asarray(getattr(p, leaf))
                                 for p in plans])
    return AggPlan(node_id=stk("node_id"), slot_mask=stk("slot_mask"),
                   parent_row=stk("parent_row"), flat_pos=stk("flat_pos"),
                   alive=stk("alive"),
                   q_budget=None if p0.q_budget is None else stk("q_budget"),
                   num_clients=p0.num_clients, num_sinks=p0.num_sinks)


def execute_batched(
    cfg: AggConfig,
    plan: AggPlan,
    grads: Array,                  # [B, K, d] per-cohort client gradients
    e: Array,                      # [B, K, d] per-cohort EF memory
    weights: Array,                # [B, K]
    *,
    global_mask: Optional[Array] = None,   # [B, d] per-cohort TCS masks
    participate: Optional[Array] = None,   # [B, K] per-cohort stragglers
) -> RoundResult:
    """B independent aggregation rounds in one launch.

    ``plan`` is either a single plan shared by every cohort (leaves
    ``[L, W]``) or a :func:`stack_plans` batch of B shape-identical plans
    (leaves ``[B, L, W]`` — heterogeneous topologies in one bucket). The
    levels run through :func:`level_step_batched`, which flattens the B
    cohorts cohort-major into one ``level_step`` launch — a single
    ``pallas_call`` per kernel stage on the fused path, instead of B.

    Every cohort's math is independent (gathers, row-parallel lanes, and a
    per-cohort scatter-add identical to :func:`execute`'s), so the result
    leaves ``[B, ...]`` are bitwise identical, per cohort, to B sequential
    ``execute`` calls — the multi-tenant contract pinned by
    tests/test_batched_rounds.py in interpret mode. One caveat: on
    *stacked* plans the per-cohort index gathers lower through
    ``take_along_axis``, and XLA may fuse the ``err_sq`` ‖e‖² reduction
    with a different association than the sequential executor — the
    aggregate, EF rows, and integer-valued §V counters (``nnz*``,
    ``bits``) stay bitwise, but ``err_sq`` is only reproduced to float
    summation order (≲1 ulp).
    """
    b, k, d = grads.shape
    if plan.num_clients != k:
        raise ValueError(f"plan has {plan.num_clients} clients, grads {k}")
    stacked = np.ndim(plan.node_id) == 3
    if stacked and plan.node_id.shape[0] != b:
        raise ValueError(f"stacked plan has {plan.node_id.shape[0]} "
                         f"cohorts, grads {b}")
    if global_mask is None:
        global_mask = jnp.zeros((b, d), grads.dtype)
    if participate is None:
        participate = jnp.ones((b, k), grads.dtype)
    participate = participate * jnp.asarray(plan.alive, grads.dtype)
    lvl = level_step_batched(cfg)

    zrow = jnp.zeros((b, 1, d), grads.dtype)
    g_ext = jnp.concatenate([grads, zrow], axis=1)
    e_ext = jnp.concatenate([e, zrow], axis=1)
    w_ext = jnp.concatenate(
        [weights, jnp.zeros((b, 1), weights.dtype)], axis=1)
    p_ext = jnp.concatenate(
        [participate, jnp.zeros((b, 1), participate.dtype)], axis=1)
    q_ext = None
    if plan.q_budget is not None:
        qb = jnp.asarray(plan.q_budget, jnp.int32)
        if not stacked and qb.ndim == 1:
            qb = jnp.broadcast_to(qb[None], (b, k))
        q_ext = jnp.concatenate([qb, jnp.zeros((b, 1), jnp.int32)], axis=1)

    def take_rows(x, ids):
        # ids [W] (shared plan) or [B, W] (stacked): per-cohort row gather
        if ids.ndim == 1:
            return x[:, ids]
        idx = ids.reshape(ids.shape + (1,) * (x.ndim - 2))
        return jnp.take_along_axis(x, idx, axis=1)

    def body(inbox, xs):
        ids, mask, par = xs
        mask_b = (mask if mask.ndim == 2
                  else jnp.broadcast_to(mask, (b,) + mask.shape))
        gamma_out, e_new, stats = lvl(
            take_rows(g_ext, ids), take_rows(inbox, ids),
            take_rows(e_ext, ids), take_rows(w_ext, ids),
            take_rows(p_ext, ids), global_mask,
            None if q_ext is None else take_rows(q_ext, ids), mask_b)
        scatter = lambda ib, go, pr, mk: ib.at[pr].add(go * mk[:, None])
        par_ax = 0 if par.ndim == 2 else None
        inbox = jax.vmap(scatter, in_axes=(0, 0, par_ax, 0))(
            inbox, gamma_out, par, mask_b)
        return inbox, (e_new, stats)

    r_sinks = plan.num_sinks
    lead = lambda x: (jnp.moveaxis(jnp.asarray(x), 1, 0) if stacked
                      else jnp.asarray(x))
    inbox0 = jnp.zeros((b, k + r_sinks + 1, d), grads.dtype)
    inbox, (e_lvl, st_lvl) = jax.lax.scan(
        body, inbox0,
        (lead(plan.node_id), lead(plan.slot_mask), lead(plan.parent_row)))

    # scan outputs are [L, B, W, ...] → cohort-major [B, L*W, ...] →
    # per-cohort client index order via flat_pos
    pos = jnp.asarray(plan.flat_pos)

    def reorder(x):
        flat = jnp.moveaxis(x, 1, 0).reshape((b, -1) + x.shape[3:])
        if pos.ndim == 1:
            return flat[:, pos]
        idx = pos.reshape(pos.shape + (1,) * (flat.ndim - 2))
        return jnp.take_along_axis(flat, idx, axis=1)

    e_new = reorder(e_lvl)
    stats = jax.tree.map(reorder, st_lvl)
    agg = inbox[:, k] if r_sinks == 1 else inbox[:, k:k + r_sinks]
    return RoundResult(aggregate=agg, e_new=e_new, stats=stats)
