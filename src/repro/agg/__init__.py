"""Plan/execute aggregation API — one entry point for every topology.

``compile_plan(topology)`` lowers a chain, permuted chain order, routed
:class:`~repro.topo.tree.AggTree`, or constellation graph into one canonical
padded ``(L, W)`` level schedule (:class:`AggPlan`); ``execute(cfg, plan,
...)`` runs one aggregation round over it — bit-exact to the paper chain and
to the tree engine it subsumes. :class:`TopologySchedule` strings plans over
time (graph-per-round or link up/down events) under a single jit
specialization; :class:`Aggregator` is the pytree-aware object API on top.

Multi-tenant batched rounds: ``execute_batched`` (host) /
``execute_sharded_batched`` (device) run B cohorts through one launch —
bitwise identical per cohort to B sequential rounds — and
:class:`RoundScheduler` packs heterogeneous cohorts into padded shape
buckets so one jit specialization per bucket serves arbitrarily many
tenants.
"""

from repro.agg.aggregator import AggState, Aggregator, RoundOut, flat_dim
from repro.agg.batching import CohortRound, RoundScheduler
from repro.agg.device import (client_mesh, execute_nested_sharded,
                              execute_sharded, execute_sharded_batched,
                              ring_chain_plan, run_nested_segments_local,
                              run_plan_clients_batched,
                              run_plan_clients_local,
                              run_plan_segments_batched,
                              run_plan_segments_local)
from repro.agg.nested import (NestedPlan, NestedResult, as_nested,
                              compile_nested, execute_nested,
                              pod_ring_nested, zero_stage_ef)
from repro.agg.plan import (AggPlan, RoundResult, as_tree, bandwidth_budgets,
                            compile_plan, execute, execute_batched,
                            stack_plans)
from repro.agg.schedule import TopologySchedule, common_shape

__all__ = [
    "AggPlan", "RoundResult", "compile_plan", "execute", "as_tree",
    "bandwidth_budgets", "TopologySchedule", "common_shape",
    "NestedPlan", "NestedResult", "compile_nested", "execute_nested",
    "as_nested", "pod_ring_nested", "zero_stage_ef",
    "Aggregator", "AggState", "RoundOut", "flat_dim",
    "client_mesh", "execute_sharded", "execute_nested_sharded",
    "ring_chain_plan", "run_plan_clients_local", "run_plan_segments_local",
    "run_nested_segments_local",
    "execute_batched", "stack_plans", "execute_sharded_batched",
    "run_plan_clients_batched", "run_plan_segments_batched",
    "CohortRound", "RoundScheduler",
]
