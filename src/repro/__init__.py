"""repro: sparse incremental aggregation for multi-hop FL, framework-scale.

Paper: "Sparse Incremental Aggregation in Multi-Hop Federated Learning"
(Mukherjee, Razmi, Dekorsy, Popovski, Matthiesen, 2024). See DESIGN.md.
"""

__version__ = "1.0.0"
