"""Training CLI driver.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
        --steps 50 --agg cl_sia --ckpt-dir /tmp/ckpt

Runs on whatever devices exist (1 CPU here; the production mesh shape is
taken from --mesh, padded down to the available device count). Resumes from
the newest checkpoint in --ckpt-dir if present.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro import compat
from repro.configs import ARCHS, get_config
from repro.core.algorithms import AggConfig, AggKind
from repro.data.synthetic import lm_batch, make_bigram_lm
from repro.launch.mesh import make_agg_plan, make_mesh
from repro.models.stubs import audio_stub_embeds, vision_stub_embeds
from repro.optim.optimizers import OptConfig
from repro.runtime.fault import StragglerModel
from repro.train.state import TrainConfig, TrainState
from repro.train.step import (build_train_step, dp_size, init_state,
                              state_shardings)


def _topology(name: str, k: int):
    """CLI topology name → something ``compile_plan`` accepts (or None)."""
    if name == "hierarchical":
        # two-stage pod/ICI nested plan (needs a pod axis: --mesh PxDxM)
        return "hierarchical"
    if name != "ring" and k <= 2:
        print(f"topology {name!r} needs >2 DP clients (have {k}); "
              f"falling back to the rotated ring")
        name = "ring"
    if name == "ring":
        return None                      # the rotated ring (paper chain)
    if name == "chain":
        return k                         # identity chain, PS at client 0
    from repro.topo import graph as tg
    from repro.topo.tree import star_tree
    if name == "star":
        return star_tree(k)
    rows = max(d for d in range(1, int(k ** 0.5) + 1) if k % d == 0)
    if name == "grid":
        if rows == 1:                    # prime K: a 1×K grid is a path
            print(f"grid needs composite K (have {k}); the 1x{k} grid "
                  f"degenerates to the chain")
        return tg.grid_graph(rows, k // rows)
    if name == "walker-delta":
        if rows == 1:                    # prime K: no orbital planes
            print(f"walker-delta needs composite K (have {k}); using the "
                  f"star topology instead")
            return star_tree(k)
        return tg.walker_delta(rows, k // rows)
    raise ValueError(f"unknown topology {name!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced per-arch config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--agg", default="cl_sia",
                    choices=[k.value for k in AggKind if k != AggKind.ROUTING])
    ap.add_argument("--q-frac", type=float, default=0.01)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--opt", default="adamw")
    ap.add_argument("--mesh", default="",
                    help="e.g. 2x2 → (data=2, model=2); default all-data")
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "chain", "star", "grid",
                             "walker-delta", "hierarchical"],
                    help="aggregation route over the K_dp clients (device-"
                         "plan lowering; 'ring' = the rotated ring; "
                         "'hierarchical' = the two-stage pod/ICI nested "
                         "plan, needs --mesh PxDxM)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--straggle-p", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        shape = (n_dev, 1)
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    mesh = make_mesh(shape, axes)
    cfg = get_config(args.arch, smoke=args.smoke)
    tc = TrainConfig(
        agg=AggConfig(kind=AggKind(args.agg), q=1),
        opt=OptConfig(name=args.opt, lr=args.lr),
        q_frac=args.q_frac,
        agg_dtype="float32" if args.smoke else "bfloat16",
        ef_dtype="float32" if args.smoke else "bfloat16",
    )

    agg_plan = make_agg_plan(mesh, _topology(args.topology, dp_size(mesh)))

    with compat.set_mesh(mesh):
        state = init_state(cfg, tc, mesh, jax.random.PRNGKey(args.seed),
                           topology=agg_plan)
        shardings = state_shardings(cfg, tc, mesh, topology=agg_plan)
        state = jax.device_put(state, shardings)
        if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
            template = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
            state = ckpt.restore(args.ckpt_dir, template,
                                 shardings=shardings)
            print(f"resumed from step {int(state.step)}")
        step_fn = jax.jit(build_train_step(cfg, tc, mesh,
                                           topology=agg_plan))

        lm = make_bigram_lm(jax.random.PRNGKey(7), cfg.vocab_size)
        sm = StragglerModel(p_straggle=args.straggle_p)
        k_dp = dp_size(mesh)
        key = jax.random.PRNGKey(args.seed + 1)
        t0 = time.time()
        for i in range(args.steps):
            key, kb, ks = jax.random.split(key, 3)
            batch = lm_batch(lm, kb, args.batch, args.seq)
            if cfg.frontend == "vision":
                fe, m = vision_stub_embeds(cfg, kb, args.batch, args.seq, 8)
                batch |= {"frontend_embeds": fe, "frontend_mask": m}
            elif cfg.frontend == "audio":
                batch |= {"frontend_embeds":
                          audio_stub_embeds(cfg, kb, args.batch, args.seq)}
            if args.straggle_p > 0:
                batch["participate"] = sm.sample(ks, k_dp)
            state, metrics = step_fn(state, batch)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {int(state.step):4d} "
                      f"loss {float(metrics['loss']):.4f} "
                      f"agg_bits {float(metrics['agg_bits']):.3e} "
                      f"({time.time()-t0:.1f}s)")
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, int(state.step), state)
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, int(state.step), state)
            print(f"checkpointed step {int(state.step)} → {args.ckpt_dir}")


if __name__ == "__main__":
    main()
