"""input_specs(): ShapeDtypeStruct stand-ins for every dry-run cell.

Weak-type-correct, shardable, zero allocation — the shapes come from the
assignment's per-arch shape sets (configs/base.py SHAPES).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec
from repro.models import model as model_mod

S = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": S((b, s), jnp.int32),
        "labels": S((b, s), jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = S((b, s, cfg.d_model), cfg.dtype)
        batch["frontend_mask"] = S((b, s), jnp.bool_)
    elif cfg.frontend == "audio":
        batch["frontend_embeds"] = S((b, s, cfg.d_model), cfg.dtype)
    return batch


def prefill_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": S((b, s), jnp.int32),
        "cache": model_mod.cache_specs(cfg, b, s),
    }
    if cfg.frontend == "vision":
        out["extra"] = {
            "frontend_embeds": S((b, s, cfg.d_model), cfg.dtype),
            "frontend_mask": S((b, s), jnp.bool_),
        }
    elif cfg.frontend == "audio":
        out["extra"] = {"frontend_embeds": S((b, s, cfg.d_model), cfg.dtype)}
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """One new token against a KV cache of seq_len (the assignment's
    definition of decode_* / long_* cells)."""
    b, s = shape.global_batch, shape.seq_len
    return {
        "token": S((b,), jnp.int32),
        "pos": S((), jnp.int32),
        "cache": model_mod.cache_specs(cfg, b, s),
    }


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)
