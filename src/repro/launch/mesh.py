"""Production mesh construction (function, not constant — importing this
module never touches jax device state). Mesh/axis-type API drift is bridged
by :mod:`repro.compat`, so these run on 0.4.x and 0.6+ runtimes alike."""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary (test-sized) mesh with the same axis conventions."""
    return compat.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Single-device mesh (CPU smoke tests / examples)."""
    return compat.make_mesh((1, 1), ("data", "model"))
