"""Production mesh construction (function, not constant — importing this
module never touches jax device state). Mesh/axis-type API drift is bridged
by :mod:`repro.compat`, so these run on 0.4.x and 0.6+ runtimes alike.

A mesh also fixes the *aggregation client set*: the combined DP axes
(``pod`` × ``data``) are the K clients of the multi-hop round.
:func:`make_agg_plan` compiles any topology over exactly that client count,
so launchers hand :func:`repro.train.step.build_train_step` an
:class:`~repro.agg.plan.AggPlan` instead of assuming the ring."""

from __future__ import annotations

from typing import Any, Optional

from repro import compat

def dp_clients(mesh) -> int:
    """Number of aggregation clients a mesh provides (pod × data size)."""
    from repro.train.step import dp_size   # the one source of the DP rule
    return dp_size(mesh)


def make_agg_plan(mesh, topology: Any = None, *,
                  pad_to: Optional[tuple] = None, q_budget=None):
    """Compile ``topology`` into an AggPlan sized for ``mesh``'s DP ring.

    ``None`` gives the rotated ring's chain plan (the paper baseline,
    bit-exact to the historic ``rotated_ring_local``); an ``AggTree``,
    chain order, ``ConstellationGraph``, or int K goes through
    :func:`repro.agg.compile_plan` with ``num_clients`` pinned to the mesh.

    Nested (staged) topologies compile to a
    :class:`~repro.agg.nested.NestedPlan` instead: ``"hierarchical"``
    gives the two-stage pod/ICI chain×chain over the mesh's (pod, data)
    axes (``core/hierarchical.py``'s schedule); a ``NestedPlan``, a routed
    :class:`~repro.topo.routing.NestedTopology`, or an explicit stage
    spec goes through :func:`repro.agg.compile_nested`. The train step
    lowers those via ``run_nested_segments_local`` (stage s on dp axis
    S−1−s, minor axis first).
    """
    from repro.agg import compile_nested, compile_plan, pod_ring_nested
    from repro.agg.device import ring_chain_plan, ring_chain_tree
    from repro.agg.nested import NestedPlan

    k = dp_clients(mesh)
    if topology is None:
        # the ring chain even when padded/budgeted — NOT path_tree(k),
        # whose reversed visiting order is a bitwise-different chain
        if pad_to is None and q_budget is None:
            return ring_chain_plan(k)
        topology = ring_chain_tree(k)
    if isinstance(topology, str) and topology == "hierarchical":
        from repro.train.step import dp_axes
        axes = dp_axes(mesh)
        if len(axes) < 2:
            raise ValueError(
                f"'hierarchical' needs two DP axes (pod, data); mesh has "
                f"{axes}")
        k_data = mesh.shape[axes[-1]]
        nested = pod_ring_nested(k // k_data, k_data, q_budget=q_budget)
        return nested if pad_to is None else nested.pad(pad_to)
    if isinstance(topology, NestedPlan) or hasattr(topology,
                                                   "nested_stages"):
        nested = compile_nested(topology, num_clients=k, q_budget=q_budget,
                                pad_to=pad_to)
        if nested.num_clients != k:
            raise ValueError(f"nested topology has {nested.num_clients} "
                             f"clients but the mesh provides {k} DP ranks")
        return nested
    return compile_plan(topology, num_clients=k, pad_to=pad_to,
                        q_budget=q_budget)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary (test-sized) mesh with the same axis conventions."""
    return compat.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Single-device mesh (CPU smoke tests / examples)."""
    return compat.make_mesh((1, 1), ("data", "model"))
