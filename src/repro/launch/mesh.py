"""Production mesh construction (function, not constant — importing this
module never touches jax device state)."""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary (test-sized) mesh with the same axis conventions."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Single-device mesh (CPU smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
