import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware:
``.lower().compile()`` must succeed on the 16×16 single-pod mesh AND the
2×16×16 multi-pod mesh for every cell; ``memory_analysis()`` proves it
fits; ``cost_analysis()`` + HLO collective parsing feed §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-130m
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_results.json
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                "benchmarks"))

from repro.configs import ARCHS, SHAPES, get_config, shape_cells
from repro.core.algorithms import AggConfig, AggKind
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.models import partition
from repro.optim.optimizers import OptConfig
from repro.train.state import TrainConfig
from repro.train.step import (build_prefill_step, build_serve_step,
                              build_train_step, init_state, state_shardings)

import hlo_analysis  # benchmarks/hlo_analysis.py
import roofline as roofline_mod  # benchmarks/roofline.py


def default_train_config(agg_kind: str = "cl_sia",
                         fsdp: bool = False) -> TrainConfig:
    return TrainConfig(agg=AggConfig(kind=AggKind(agg_kind), q=1),
                       opt=OptConfig(name="adamw", lr=3e-4),
                       q_frac=0.01, fsdp_compute=fsdp)


def _mem_dict(ma) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["peak_bytes_estimate"] = (out.get("argument_size_in_bytes", 0)
                                  + out.get("output_size_in_bytes", 0)
                                  + out.get("temp_size_in_bytes", 0)
                                  - out.get("alias_size_in_bytes", 0))
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               agg_kind: str = "cl_sia", fsdp: bool = False,
               verbose: bool = True) -> dict:
    """Lower + compile one cell; return the §Dry-run/§Roofline record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for a in mesh.axis_names:
        chips *= mesh.shape[a]
    tc = default_train_config(agg_kind, fsdp)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "agg": agg_kind, "status": "ok"}
    t0 = time.time()

    with compat.set_mesh(mesh):
        if shape.kind == "train":
            train_step = build_train_step(cfg, tc, mesh)
            state_sds = jax.eval_shape(
                lambda: init_state(cfg, tc, mesh, jax.random.PRNGKey(0)))
            state_sh = state_shardings(cfg, tc, mesh)
            batch_sds = specs_mod.input_specs(cfg, shape_name)
            batch_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                partition.batch_pspecs(cfg, mesh, shape.global_batch),
                is_leaf=lambda x: isinstance(x, P))
            lowered = jax.jit(
                train_step, in_shardings=(state_sh, batch_sh),
            ).lower(state_sds, batch_sds)
        else:
            from repro.models import model as model_mod
            ins = specs_mod.input_specs(cfg, shape_name)
            params_sds = jax.eval_shape(
                lambda: model_mod.init_params(cfg, jax.random.PRNGKey(0)))
            ns = lambda s: NamedSharding(mesh, s)
            p_sh = jax.tree.map(ns, partition.param_pspecs(cfg, mesh),
                                is_leaf=lambda x: isinstance(x, P))
            c_sh = jax.tree.map(ns, partition.cache_pspecs(
                cfg, mesh, shape.global_batch),
                is_leaf=lambda x: isinstance(x, P))
            dpx = partition.batch_axes(mesh)
            dp_total = 1
            for a in dpx:
                dp_total *= mesh.shape[a]
            b_ok = shape.global_batch % dp_total == 0
            if shape.kind == "prefill":
                fn = build_prefill_step(cfg, mesh)
                b_sh = ns(P(dpx if b_ok else None, None))
                args = [params_sds, ins["cache"], ins["tokens"]]
                shardings = [p_sh, c_sh, b_sh]
                if "extra" in ins:
                    e_sh = jax.tree.map(
                        lambda l: ns(P(dpx if b_ok else None,
                                       *([None] * (len(l.shape) - 1)))),
                        ins["extra"])
                    args.append(ins["extra"])
                    shardings.append(e_sh)
                lowered = jax.jit(fn, in_shardings=tuple(shardings)).lower(
                    *args)
            else:  # decode: one token against a seq_len-deep cache
                fn = build_serve_step(cfg, mesh)
                tok_sh = ns(P(dpx if b_ok else None))
                lowered = jax.jit(
                    fn, in_shardings=(p_sh, c_sh, tok_sh, ns(P()))).lower(
                    params_sds, ins["cache"], ins["token"], ins["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # trip-count-aware analysis (cost_analysis() visits loop bodies once —
    # wrong by ~num_layers for scanned stacks; see hlo_analysis.py)
    cost = hlo_analysis.analyze(hlo)
    mf = roofline_mod.model_flops_for(cfg, shape, shape.kind)
    rl = roofline_mod.Roofline(
        flops=cost.flops,
        bytes_accessed=cost.hbm_bytes,
        wire_bytes=cost.wire_bytes,
        model_flops=mf,
        chips=chips,
    )
    rec.update({
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": _mem_dict(ma),
        "cost_analysis_raw": {k: float(v) for k, v in list(ca.items())
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed")},
        "collectives": cost.collective_dict(),
        "roofline": rl.as_dict(),
    })
    if verbose:
        mem = rec["memory_analysis"]
        print(f"[{rec['mesh']}] {arch} × {shape_name}: "
              f"peak≈{mem['peak_bytes_estimate']/1e9:.2f} GB/dev, "
              f"flops/dev={rl.flops:.3e}, wire={cost.wire_bytes/1e6:.1f} MB, "
              f"bottleneck={rl.bottleneck}, "
              f"roofline={rl.roofline_fraction:.3f} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory_analysis: {mem}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--agg", default="cl_sia")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    existing = {}
    if args.out and args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for r in json.load(f):
                existing[(r["arch"], r["shape"], r["mesh"], r.get("agg"))] = r

    cells = []
    if args.all:
        for arch in ARCHS:
            cfg = get_config(arch)
            for shape_name in shape_cells(cfg):
                cells.append((arch, shape_name))
    else:
        arch = args.arch or "mamba2-130m"
        names = [args.shape] if args.shape else shape_cells(get_config(arch))
        cells = [(arch, s) for s in names]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = list(existing.values())
    failures = 0
    for arch, shape_name in cells:
        for mp in meshes:
            key = (arch, shape_name, "2x16x16" if mp else "16x16", args.agg)
            if key in existing:
                print(f"skip cached {key}")
                continue
            try:
                rec = lower_cell(arch, shape_name, multi_pod=mp,
                                 agg_kind=args.agg, fsdp=args.fsdp)
            except Exception as e:  # a failure here is a bug in our system
                failures += 1
                rec = {"arch": arch, "shape": shape_name,
                       "mesh": "2x16x16" if mp else "16x16",
                       "agg": args.agg, "status": "FAIL",
                       "error": f"{type(e).__name__}: {e}"}
                print(f"FAIL {arch} × {shape_name} ({rec['mesh']}): "
                      f"{rec['error']}")
                traceback.print_exc()
            results.append(rec)
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"\n{len(results)} cells, {failures} failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
