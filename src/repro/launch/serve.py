"""Serving CLI: batched prefill + decode loop with a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_mesh
from repro.models import model as model_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    max_len = args.prompt_len + args.gen

    with compat.set_mesh(mesh):
        params = model_mod.init_params(cfg, jax.random.PRNGKey(args.seed))
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
        cache = model_mod.init_cache(cfg, args.batch, max_len)

        prefill = jax.jit(
            lambda p, t, c: model_mod.prefill(cfg, p, t, c))
        decode = jax.jit(
            lambda p, c, t, pos: model_mod.decode_step(cfg, p, c, t, pos))

        t0 = time.time()
        logits, cache = prefill(params, prompts, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = [tok]
        for i in range(args.gen - 1):
            logits, cache = decode(params, cache, tok,
                                   jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
        gen = jnp.stack(out, axis=1)
        dt = time.time() - t0
        print(f"arch={cfg.name} batch={args.batch} "
              f"prompt={args.prompt_len} generated={gen.shape[1]} tokens "
              f"in {dt:.2f}s ({args.batch*gen.shape[1]/dt:.1f} tok/s)")
        print("sample generations (token ids):")
        for row in list(gen[:2]):
            print("  ", list(map(int, row)))


if __name__ == "__main__":
    main()
